#!/usr/bin/env python
"""Fail when a file meant to stay small regrows.

  python tools/check_sizes.py          (exit 1 on any violation)

The serving-engine facade was deliberately reduced to a thin scheduling
loop when the pipeline split into serve/{admission,pool,executor,stats}
— new scheduling/caching/stats logic belongs in those layers, not back
in the facade.  This check (wired into ``make lint`` and the fast test
tier) makes that an enforced property instead of a convention.
"""
from __future__ import annotations

from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# path (repo-relative) -> max line count
LIMITS = {
    "src/repro/serve/render_engine.py": 250,
    # the scheduler is a policy seam, not a second engine: selection,
    # arrival gating, and shed decisions only — budget heuristics that
    # grow past this belong in their own module
    "src/repro/serve/scheduler.py": 330,
}


def violations():
    out = []
    for rel, limit in LIMITS.items():
        path = REPO / rel
        if not path.exists():
            out.append(f"{rel}: MISSING (size-limited file was removed "
                       f"without updating tools/check_sizes.py)")
            continue
        n = len(path.read_text().splitlines())
        if n > limit:
            out.append(f"{rel}: {n} lines > limit {limit} — move logic "
                       f"into the serve/ pipeline layers instead")
    return out


def main() -> int:
    probs = violations()
    for p in probs:
        print(p)
    print(f"[check_sizes] {len(LIMITS)} limits, {len(probs)} violations")
    return 1 if probs else 0


if __name__ == "__main__":
    raise SystemExit(main())
